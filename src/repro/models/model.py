"""Model assembly: config -> parameter specs -> train / prefill / decode fns.

The layer stack is ``cfg.layer_pattern`` repeated ``cfg.n_groups`` times and
lowers to ONE ``lax.scan`` over groups with per-slot parameters stacked on the
leading axis; heterogeneous stacks (gemma3 local:global, zamba2 mamba+shared
attention, VLM cross-attn every 5th layer, whisper enc-dec) are all patterns.
The scan body is rematerialized (``jax.checkpoint``) in full-sequence modes.

Caches: decode state is a pytree built from the same ParamSpec machinery as
parameters (shape + logical sharding axes in one place), with ring-buffer KV
for windowed layers (see models/attention.py) and recurrent states for
mamba2 / rwkv6 slots.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..configs.base import LayerSpec, ModelConfig
from ..sharding import constrain
from . import mamba2 as m2
from . import rwkv6 as rw
from .attention import (attn_specs, cross_decode_attention, decode_attention,
                        multihead_attention)
from .layers import embed_specs, mlp, mlp_specs, rmsnorm, rmsnorm_specs, \
    sinusoidal_positions, unembed
from .moe import moe_ffn, moe_specs
from .params import ParamSpec, abstract, axes_tree, init_params, stack_specs

Array = jnp.ndarray

AUX_LOSS_WEIGHT = 0.01
XENT_CHUNK = 512


def _use_rope(cfg: ModelConfig) -> bool:
    return cfg.family != "audio"


def cast_params(params: dict, dtype) -> dict:
    """Cast floating params to the compute dtype ONCE, before the layer stack.

    With FSDP ('embed' sharded over 'data'), every layer's weights are
    all-gathered per use; casting the *sharded* master copy first makes those
    gathers move bf16 instead of f32 — at 90B-param scale that halves ~12 TB
    of per-step collective traffic and the gathered VMEM/HBM footprint.  The
    cast's VJP re-accumulates gradients in f32 against the master params."""
    return jax.tree.map(
        lambda p: p.astype(dtype)
        if jnp.issubdtype(p.dtype, jnp.floating) else p, params)


def _shared_window(cfg: ModelConfig) -> int:
    for s in cfg.layer_pattern:
        if s.shared_attn and s.window:
            return s.window
    return 4096


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------

def _slot_specs(cfg: ModelConfig, slot: LayerSpec) -> dict:
    d = cfg.d_model
    s: dict = {"norm1": rmsnorm_specs(d)}
    if slot.kind == "attn":
        s["attn"] = attn_specs(d, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
                               cfg.use_qk_norm)
        s["norm2"] = rmsnorm_specs(d)
        s["ffn"] = moe_specs(d, cfg.moe) if slot.moe else mlp_specs(d, cfg.d_ff)
    elif slot.kind == "mamba2":
        s["mixer"] = m2.mamba2_specs(d, cfg.ssm)
    elif slot.kind == "rwkv6":
        s["mixer"] = rw.rwkv6_specs(d, cfg.n_heads, cfg.head_dim, cfg.d_ff)
        s["norm2"] = rmsnorm_specs(d)
    else:
        raise ValueError(f"unknown slot kind {slot.kind}")
    if slot.cross_attn:
        s["cross_norm"] = rmsnorm_specs(d)
        s["cross_attn"] = attn_specs(d, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim)
    return s


def param_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    slots = {f"slot{i}": _slot_specs(cfg, s)
             for i, s in enumerate(cfg.layer_pattern)}
    specs: dict = {
        "embed": embed_specs(cfg.vocab_size, d),
        "groups": stack_specs(slots, cfg.n_groups),
        "final_norm": rmsnorm_specs(d),
    }
    if cfg.has_shared_attn:
        specs["shared"] = {
            "norm": rmsnorm_specs(d),
            "attn": attn_specs(d, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim),
        }
    if not cfg.tie_embeddings:
        specs["unembed"] = {"table": ParamSpec((cfg.vocab_size, d),
                                               ("vocab", "embed"), scale=0.02)}
    if cfg.encoder is not None:
        enc_slot = {
            "norm1": rmsnorm_specs(d),
            "attn": attn_specs(d, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim),
            "norm2": rmsnorm_specs(d),
            "ffn": mlp_specs(d, cfg.d_ff),
        }
        specs["encoder"] = {
            "groups": stack_specs({"slot0": enc_slot}, cfg.encoder.n_layers),
            "final_norm": rmsnorm_specs(d),
        }
    return specs


def init(cfg: ModelConfig, key: jax.Array, dtype=jnp.float32):
    return init_params(param_specs(cfg), key, dtype)


def abstract_params(cfg: ModelConfig, dtype=jnp.float32):
    return abstract(param_specs(cfg), dtype)


def param_axes(cfg: ModelConfig):
    return axes_tree(param_specs(cfg))


# ---------------------------------------------------------------------------
# cache specs
# ---------------------------------------------------------------------------

def _cache_len(seq_len: int, window: int) -> int:
    return seq_len if window == 0 else min(seq_len, window)


def _slot_cache_specs(cfg: ModelConfig, slot: LayerSpec, batch: int,
                      seq_len: int) -> dict:
    kv, dh = cfg.n_kv_heads, cfg.head_dim
    c: dict = {}
    if slot.kind == "attn":
        tc = _cache_len(seq_len, slot.window)
        kv_axes = (None, "batch", "seq_shard", "kv_heads", "head_dim")
        c["k"] = ParamSpec((cfg.n_groups, batch, tc, kv, dh), kv_axes, init="zeros")
        c["v"] = ParamSpec((cfg.n_groups, batch, tc, kv, dh), kv_axes, init="zeros")
    elif slot.kind == "mamba2":
        dims = m2.mamba2_dims(cfg.d_model, cfg.ssm)
        c["conv"] = ParamSpec((cfg.n_groups, batch, dims.conv_width - 1, dims.conv_dim),
                              (None, "batch", None, "mlp"), init="zeros")
        c["ssm"] = ParamSpec((cfg.n_groups, batch, dims.n_heads, dims.head_dim,
                              dims.state),
                             (None, "batch", "ssm_heads", None, None), init="zeros")
    elif slot.kind == "rwkv6":
        c["wkv"] = ParamSpec((cfg.n_groups, batch, cfg.n_heads, cfg.head_dim,
                              cfg.head_dim),
                             (None, "batch", "heads", None, None), init="zeros")
        c["tm_shift"] = ParamSpec((cfg.n_groups, batch, cfg.d_model),
                                  (None, "batch", None), init="zeros")
        c["cm_shift"] = ParamSpec((cfg.n_groups, batch, cfg.d_model),
                                  (None, "batch", None), init="zeros")
    if slot.shared_attn:
        tc = _cache_len(seq_len, _shared_window(cfg))
        kv_axes = (None, "batch", "seq_shard", "kv_heads", "head_dim")
        c["shared_k"] = ParamSpec((cfg.n_groups, batch, tc, kv, dh), kv_axes,
                                  init="zeros")
        c["shared_v"] = ParamSpec((cfg.n_groups, batch, tc, kv, dh), kv_axes,
                                  init="zeros")
    if slot.cross_attn:
        l = cfg.cross_attn_source_len
        kv_axes = (None, "batch", None, "kv_heads", "head_dim")
        c["cross_k"] = ParamSpec((cfg.n_groups, batch, l, kv, dh), kv_axes,
                                 init="zeros")
        c["cross_v"] = ParamSpec((cfg.n_groups, batch, l, kv, dh), kv_axes,
                                 init="zeros")
    return c


def cache_specs(cfg: ModelConfig, batch: int, seq_len: int) -> dict:
    return {f"slot{i}": _slot_cache_specs(cfg, s, batch, seq_len)
            for i, s in enumerate(cfg.layer_pattern)}


def init_cache(cfg: ModelConfig, batch: int, seq_len: int, dtype=jnp.float32):
    specs = cache_specs(cfg, batch, seq_len)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, dtype), specs,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


def abstract_cache(cfg: ModelConfig, batch: int, seq_len: int, dtype=jnp.float32):
    return abstract(cache_specs(cfg, batch, seq_len), dtype)


def cache_axes(cfg: ModelConfig, batch: int, seq_len: int):
    return axes_tree(cache_specs(cfg, batch, seq_len))


# ---------------------------------------------------------------------------
# full-sequence forward (train / prefill)
# ---------------------------------------------------------------------------

def _ring_from_prefill(k: Array, tc: int) -> Array:
    """Convert prefill keys (B,S,KV,D) to ring-cache layout (B,Tc,KV,D):
    token at absolute position p lives at ring row p % Tc."""
    b, s = k.shape[:2]
    if s <= tc:
        pad = [(0, 0)] * k.ndim
        pad[1] = (0, tc - s)
        return jnp.pad(k, pad)
    return jnp.roll(k[:, s - tc:], shift=s % tc, axis=1)


def _apply_slot_full(cfg: ModelConfig, slot: LayerSpec, sp: dict, x: Array, *,
                     positions: Array, k_pos: Array, cross_src: Array | None,
                     shared_params: dict | None, causal: bool, emit_cache: bool,
                     cache_len: int):
    """One pattern slot over a full sequence.  Returns (x, cache_dict, aux)."""
    aux = jnp.zeros((), jnp.float32)
    cache: dict = {}
    rope = cfg.rope_theta if _use_rope(cfg) else 0.0
    if slot.kind == "attn":
        h = rmsnorm(sp["norm1"], x, cfg.norm_eps)
        y, (k, v) = multihead_attention(
            sp["attn"], h, h, q_pos=positions, k_pos=k_pos, causal=causal,
            window=slot.window, softcap=cfg.attn_logit_softcap,
            qk_norm=cfg.use_qk_norm, rope_theta=rope, norm_eps=cfg.norm_eps,
            return_kv=True)
        x = x + y
        if emit_cache:
            tc = _cache_len(cache_len, slot.window)
            cache["k"] = _ring_from_prefill(k, tc)
            cache["v"] = _ring_from_prefill(v, tc)
    elif slot.kind == "mamba2":
        h = rmsnorm(sp["norm1"], x, cfg.norm_eps)
        y, (conv_st, ssm_st) = m2.mamba2_block(sp["mixer"], h, cfg.ssm)
        x = x + y
        if emit_cache:
            cache["conv"], cache["ssm"] = conv_st, ssm_st
    elif slot.kind == "rwkv6":
        h = rmsnorm(sp["norm1"], x, cfg.norm_eps)
        y, (tm_shift, wkv_st) = rw.time_mix(sp["mixer"], h)
        x = x + y
        h2 = rmsnorm(sp["norm2"], x, cfg.norm_eps)
        y2, cm_shift = rw.channel_mix(sp["mixer"], h2)
        x = x + y2
        if emit_cache:
            cache["wkv"], cache["tm_shift"], cache["cm_shift"] = \
                wkv_st, tm_shift, cm_shift

    if slot.shared_attn:
        h = rmsnorm(shared_params["norm"], x, cfg.norm_eps)
        win = _shared_window(cfg)
        y, (k, v) = multihead_attention(
            shared_params["attn"], h, h, q_pos=positions, k_pos=k_pos,
            causal=causal, window=win, rope_theta=rope, norm_eps=cfg.norm_eps,
            return_kv=True)
        x = x + y
        if emit_cache:
            tc = _cache_len(cache_len, win)
            cache["shared_k"] = _ring_from_prefill(k, tc)
            cache["shared_v"] = _ring_from_prefill(v, tc)

    if slot.cross_attn:
        h = rmsnorm(sp["cross_norm"], x, cfg.norm_eps)
        l = cross_src.shape[1]
        y, (ck, cv) = multihead_attention(
            sp["cross_attn"], h, cross_src, q_pos=positions,
            k_pos=jnp.arange(l, dtype=jnp.int32), causal=False, rope_theta=0.0,
            norm_eps=cfg.norm_eps, return_kv=True)
        x = x + y
        if emit_cache:
            cache["cross_k"], cache["cross_v"] = ck, cv

    if slot.kind == "attn":
        h = rmsnorm(sp["norm2"], x, cfg.norm_eps)
        if slot.moe:
            y, a = moe_ffn(sp["ffn"], h, cfg.moe)
            aux = aux + a
        else:
            y = mlp(sp["ffn"], h)
        x = x + y
    return x, cache, aux


# When True, the layer-group stack is a Python loop instead of lax.scan.
# Larger HLO / slower compiles, but GSPMD partitions per-layer gradients
# directly instead of through scan-carry cotangents (see EXPERIMENTS.md §Perf:
# the scan path materializes FULL f32 per-group gradients).
UNROLL_GROUPS = False


def _backbone_full(cfg: ModelConfig, params: dict, h: Array, positions: Array, *,
                   cross_src: Array | None, causal: bool, emit_cache: bool,
                   cache_len: int):
    """Scan the pattern groups over a full sequence."""
    k_pos = jnp.arange(h.shape[1], dtype=jnp.int32)
    shared = params.get("shared")

    # long patterns (gemma3's period 26 => n_groups == 1) get no remat from
    # the group scan itself; rematerialize per slot instead
    remat_slots = cfg.period > 4

    def body(carry, gp):
        x, aux = carry
        caches = {}
        for i, slot in enumerate(cfg.layer_pattern):
            def apply_i(sp_, x_, slot_=slot):
                return _apply_slot_full(
                    cfg, slot_, sp_, x_, positions=positions, k_pos=k_pos,
                    cross_src=cross_src, shared_params=shared, causal=causal,
                    emit_cache=emit_cache, cache_len=cache_len)
            fn = jax.checkpoint(apply_i) if remat_slots else apply_i
            x, c, a = fn(gp[f"slot{i}"], x)
            caches[f"slot{i}"] = c
            aux = aux + a
        # re-shard the carry seq-wise (SP): the remat-saved per-group stack
        # then stores 1/model_parallel of every activation
        x = constrain(x, ("batch", "act_seq", None))
        return (x, aux), (caches if emit_cache else None)

    body = jax.checkpoint(body)
    if UNROLL_GROUPS:
        carry = (h, jnp.zeros((), jnp.float32))
        cache_list = []
        for g in range(cfg.n_groups):
            gp = jax.tree.map(lambda p: p[g], params["groups"])
            carry, caches_g = body(carry, gp)
            cache_list.append(caches_g)
        h, aux = carry
        caches = (jax.tree.map(lambda *xs: jnp.stack(xs), *cache_list)
                  if emit_cache else None)
    else:
        (h, aux), caches = jax.lax.scan(body, (h, jnp.zeros((), jnp.float32)),
                                        params["groups"])
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    return h, aux, caches


def _encode(cfg: ModelConfig, params: dict, frames: Array) -> Array:
    """Whisper-style bidirectional encoder over precomputed frame embeddings."""
    enc = params["encoder"]
    b, l, _ = frames.shape
    pos = jnp.arange(l, dtype=jnp.int32)
    h = frames + sinusoidal_positions(pos, cfg.d_model)[None].astype(frames.dtype)

    def body(x, gp):
        sp = gp["slot0"]
        y = multihead_attention(sp["attn"], rmsnorm(sp["norm1"], x, cfg.norm_eps),
                                rmsnorm(sp["norm1"], x, cfg.norm_eps),
                                q_pos=pos[None].repeat(b, 0), k_pos=pos,
                                causal=False, rope_theta=0.0, norm_eps=cfg.norm_eps)
        x = x + y
        x = x + mlp(sp["ffn"], rmsnorm(sp["norm2"], x, cfg.norm_eps))
        return x, None

    h, _ = jax.lax.scan(jax.checkpoint(body), h, enc["groups"])
    return rmsnorm(enc["final_norm"], h, cfg.norm_eps)


def _embed_tokens(cfg: ModelConfig, params: dict, tokens: Array, positions: Array,
                  dtype) -> Array:
    h = params["embed"]["table"].astype(dtype)[tokens]
    if not _use_rope(cfg):
        h = h + sinusoidal_positions(positions, cfg.d_model).astype(dtype)
    return h


def _cross_source(cfg: ModelConfig, params: dict, batch: dict[str, Array],
                  dtype) -> Array | None:
    if cfg.encoder is not None:
        return _encode(cfg, params, batch["frames"].astype(dtype))
    if cfg.cross_attn_source_len:
        return batch["patches"].astype(dtype)
    return None


def forward(cfg: ModelConfig, params: dict, batch: dict[str, Array], *,
            emit_cache: bool = False, max_cache_len: int = 0,
            dtype=jnp.bfloat16):
    """Full-sequence forward.  Returns (hidden (B,S,D), aux, caches).

    ``max_cache_len`` sizes the emitted decode caches (>= prompt length +
    planned decode steps); defaults to the prompt length.
    """
    tokens = batch["tokens"]
    params = cast_params(params, dtype)
    b, s = tokens.shape
    positions = jnp.arange(s, dtype=jnp.int32)[None].repeat(b, 0)
    h = _embed_tokens(cfg, params, tokens, positions, dtype)
    h = constrain(h, ("batch", None, None))
    cross_src = _cross_source(cfg, params, batch, dtype)
    return _backbone_full(cfg, params, h, positions, cross_src=cross_src,
                          causal=True, emit_cache=emit_cache,
                          cache_len=max(max_cache_len, s))


def _logit_table(cfg: ModelConfig, params: dict) -> Array:
    return (params["embed"]["table"] if cfg.tie_embeddings
            else params["unembed"]["table"])


def chunked_xent(h: Array, table: Array, labels: Array,
                 chunk: int = XENT_CHUNK) -> Array:
    """Mean cross-entropy without materializing (B,S,V) logits: scan over
    sequence chunks, f32 accumulation on the MXU."""
    b, s, d = h.shape
    nc = max(1, -(-s // chunk))
    pad = nc * chunk - s
    hp = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
    lp = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    hc = hp.reshape(b, nc, chunk, d).transpose(1, 0, 2, 3)
    lc = lp.reshape(b, nc, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def body(tot, xs):
        hb, lb = xs
        logits = jnp.einsum("bcd,vd->bcv", hb, table.astype(hb.dtype),
                            preferred_element_type=jnp.float32)
        logits = constrain(logits, ("batch", None, "vocab"))
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, jnp.maximum(lb, 0)[..., None],
                                   axis=-1)[..., 0]
        valid = (lb >= 0).astype(jnp.float32)
        return tot + jnp.sum((lse - gold) * valid), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hc, lc))
    return total / jnp.maximum(jnp.sum(labels >= 0).astype(jnp.float32), 1.0)


def loss_fn(cfg: ModelConfig, params: dict, batch: dict[str, Array], *,
            dtype=jnp.bfloat16):
    h, aux, _ = forward(cfg, params, batch, dtype=dtype)
    loss = chunked_xent(h, _logit_table(cfg, params), batch["labels"])
    total = loss + AUX_LOSS_WEIGHT * aux
    return total, {"loss": loss, "aux_loss": aux}


def prefill(cfg: ModelConfig, params: dict, batch: dict[str, Array], *,
            max_cache_len: int = 0, dtype=jnp.bfloat16):
    """Returns (last-token logits (B,V), caches, pos (B,))."""
    h, _, caches = forward(cfg, params, batch, emit_cache=True,
                           max_cache_len=max_cache_len, dtype=dtype)
    last = h[:, -1]
    logits = last.astype(jnp.float32) @ _logit_table(cfg, params).astype(
        jnp.float32).T
    b, s = batch["tokens"].shape
    return logits, caches, jnp.full((b,), s, jnp.int32)


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def _apply_slot_decode(cfg: ModelConfig, slot: LayerSpec, sp: dict, x: Array, *,
                       pos: Array, cache: dict, shared_params: dict | None):
    new_cache = dict(cache)
    rope = cfg.rope_theta if _use_rope(cfg) else 0.0
    if slot.kind == "attn":
        h = rmsnorm(sp["norm1"], x, cfg.norm_eps)
        y, nk, nv = decode_attention(sp["attn"], h, cache["k"], cache["v"],
                                     pos=pos, softcap=cfg.attn_logit_softcap,
                                     qk_norm=cfg.use_qk_norm, rope_theta=rope,
                                     norm_eps=cfg.norm_eps)
        x = x + y
        new_cache["k"], new_cache["v"] = nk, nv
    elif slot.kind == "mamba2":
        h = rmsnorm(sp["norm1"], x, cfg.norm_eps)
        y, (conv_st, ssm_st) = m2.mamba2_block(
            sp["mixer"], h, cfg.ssm, conv_state=cache["conv"],
            ssm_state=cache["ssm"], decode=True)
        x = x + y
        new_cache["conv"], new_cache["ssm"] = conv_st, ssm_st
    elif slot.kind == "rwkv6":
        h = rmsnorm(sp["norm1"], x, cfg.norm_eps)
        y, (tm_shift, wkv_st) = rw.time_mix(
            sp["mixer"], h, shift_state=cache["tm_shift"],
            wkv_state=cache["wkv"], decode=True)
        x = x + y
        h2 = rmsnorm(sp["norm2"], x, cfg.norm_eps)
        y2, cm_shift = rw.channel_mix(sp["mixer"], h2,
                                      shift_state=cache["cm_shift"])
        x = x + y2
        new_cache["wkv"], new_cache["tm_shift"], new_cache["cm_shift"] = \
            wkv_st, tm_shift, cm_shift

    if slot.shared_attn:
        h = rmsnorm(shared_params["norm"], x, cfg.norm_eps)
        y, nk, nv = decode_attention(shared_params["attn"], h,
                                     cache["shared_k"], cache["shared_v"],
                                     pos=pos, rope_theta=rope,
                                     norm_eps=cfg.norm_eps)
        x = x + y
        new_cache["shared_k"], new_cache["shared_v"] = nk, nv

    if slot.cross_attn:
        h = rmsnorm(sp["cross_norm"], x, cfg.norm_eps)
        y = cross_decode_attention(sp["cross_attn"], h, cache["cross_k"],
                                   cache["cross_v"], norm_eps=cfg.norm_eps)
        x = x + y

    if slot.kind == "attn":
        h = rmsnorm(sp["norm2"], x, cfg.norm_eps)
        if slot.moe:
            y, _ = moe_ffn(sp["ffn"], h, cfg.moe)
        else:
            y = mlp(sp["ffn"], h)
        x = x + y
    return x, new_cache


def decode_step(cfg: ModelConfig, params: dict, cache: dict, tokens: Array,
                pos: Array, *, dtype=jnp.bfloat16):
    """One serving step: tokens (B,1) int32, pos (B,) absolute positions.
    Returns (logits (B,V) f32, new_cache)."""
    params = cast_params(params, dtype)
    h = _embed_tokens(cfg, params, tokens, pos[:, None], dtype)
    shared = params.get("shared")

    def body(x, xs):
        gp, gcache = xs
        new_caches = {}
        for i, slot in enumerate(cfg.layer_pattern):
            x, nc = _apply_slot_decode(cfg, slot, gp[f"slot{i}"], x, pos=pos,
                                       cache=gcache[f"slot{i}"],
                                       shared_params=shared)
            new_caches[f"slot{i}"] = nc
        return x, new_caches

    h, new_cache = jax.lax.scan(body, h, (params["groups"], cache))
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = h[:, 0].astype(jnp.float32) @ _logit_table(cfg, params).astype(
        jnp.float32).T
    return logits, new_cache


def count_params(cfg: ModelConfig) -> int:
    specs = param_specs(cfg)
    return sum(math.prod(s.shape) for s in jax.tree.leaves(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec)))
