"""BEYOND-PAPER: WLSH kernel attention — the paper's estimator as a
sub-quadratic attention layer (DESIGN.md §4).

Softmax attention is replaced by shift-invariant kernel attention

    out_i = sum_j k(zq_i - zk_j) v_j / sum_j k(zq_i - zk_j)

with k the WLSH kernel (Def. 8) estimated by the bucket-load trick over
VALUES: per LSH instance, keys deposit (weight_j * v_j, weight_j) into their
bucket, and each query reads its own bucket back — O(S·m) instead of O(S²).
Queries/keys are first projected to a low hash dimension (collision
probability decays with dimension, paper §3), with the projection part of the
per-instance randomness.

Bidirectional (encoder) form; the causal form needs per-bucket prefix sums
(sort by (bucket, position) + segment cumsum) and is left as the documented
extension point.  Validated in tests against the explicit kernel-attention
oracle built from the analytic WLSH kernel.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..core.bucket_fns import BucketFn
from ..core.lsh import GammaPDF, _fmix32

Array = jnp.ndarray


class WLSHAttnParams(NamedTuple):
    proj: Array   # (m, D, dh)  random projections to hash space
    w: Array      # (m, dh)     bucket widths ~ p(.)
    z: Array      # (m, dh)     offsets ~ Unif[0, w]
    r1: Array     # (m, dh)     universal hash keys (uint32, odd)


def sample_wlsh_attn(key: jax.Array, m: int, d_head: int, *, d_hash: int = 4,
                     pdf: GammaPDF = GammaPDF(2.0, 1.0),
                     lengthscale: float = 1.0) -> WLSHAttnParams:
    kp, kw, kz, kr = jax.random.split(key, 4)
    proj = jax.random.normal(kp, (m, d_head, d_hash)) / jnp.sqrt(d_head)
    w = jax.random.gamma(kw, pdf.shape, (m, d_hash)) * pdf.scale * lengthscale
    z = jax.random.uniform(kz, (m, d_hash)) * w
    r1 = jax.random.randint(kr, (m, d_hash), 0, jnp.iinfo(jnp.int32).max,
                            dtype=jnp.int32)
    r1 = (r1.astype(jnp.uint32) << 1) | jnp.uint32(1)
    return WLSHAttnParams(proj=proj, w=w, z=z, r1=r1)


def _hash_weight(x: Array, params: WLSHAttnParams, f: BucketFn,
                 table_size: int):
    """x (..., S, D) -> (slot (m, ..., S) int32, weight (m, ..., S) f32)."""
    zx = jnp.einsum("...sd,mdh->m...sh", x.astype(jnp.float32), params.proj)
    shape = (params.w.shape[0],) + (1,) * (zx.ndim - 2) + params.w.shape[1:]
    w = params.w.reshape(shape)
    z = params.z.reshape(shape)
    t = (zx - z) / w
    h = jnp.round(t)
    weight = jnp.prod(f(h - t), axis=-1)
    hi = h.astype(jnp.int32).astype(jnp.uint32)
    key1 = _fmix32(jnp.sum(hi * params.r1.reshape(shape).astype(jnp.uint32),
                           axis=-1, dtype=jnp.uint32))
    slot = (key1 & jnp.uint32(table_size - 1)).astype(jnp.int32)
    return slot, weight


def wlsh_attention(q: Array, k: Array, v: Array, params: WLSHAttnParams,
                   f: BucketFn, *, table_size: int = 1024,
                   eps: float = 1e-6) -> Array:
    """Bidirectional WLSH kernel attention.

    q, k (B, S, H, D); v (B, S, H, Dv) -> (B, S, H, Dv).  Cost O(B·H·S·m·Dv)
    versus softmax's O(B·H·S²·Dv): sub-quadratic whenever m << S.
    """
    b, s, nh, dv = v.shape
    if table_size & (table_size - 1):
        raise ValueError("table_size must be a power of two")
    # merge batch/head; hash queries and keys under the SAME instances
    qf = q.transpose(0, 2, 1, 3).reshape(b * nh, s, q.shape[-1])
    kf = k.transpose(0, 2, 1, 3).reshape(b * nh, s, k.shape[-1])
    vf = v.transpose(0, 2, 1, 3).reshape(b * nh, s, dv).astype(jnp.float32)

    slot_q, w_q = _hash_weight(qf, params, f, table_size)   # (m, BH, S)
    slot_k, w_k = _hash_weight(kf, params, f, table_size)

    m = slot_q.shape[0]
    bh = b * nh
    # bucket loads over keys: values and normalizer in one table
    vals1 = jnp.concatenate([vf, jnp.ones((bh, s, 1), jnp.float32)], -1)
    contrib = w_k[..., None] * vals1[None]                  # (m, BH, S, Dv+1)
    tables = jnp.zeros((m, bh, table_size, dv + 1), jnp.float32)
    midx = jnp.arange(m, dtype=jnp.int32)[:, None, None]
    bidx = jnp.arange(bh, dtype=jnp.int32)[None, :, None]
    tables = tables.at[midx, bidx, slot_k].add(contrib)
    # query readout: each query reads its own bucket, scaled by its weight
    read = tables[midx, bidx, slot_q] * w_q[..., None]      # (m, BH, S, Dv+1)
    acc = jnp.sum(read, axis=0)                             # sum over instances
    out = acc[..., :dv] / jnp.maximum(acc[..., dv:], eps * m)
    return out.reshape(b, nh, s, dv).transpose(0, 2, 1, 3).astype(v.dtype)


def kernel_attention_oracle(q: Array, k: Array, v: Array, kernel_1d,
                            params: WLSHAttnParams, eps: float = 1e-6):
    """Explicit O(S²) kernel attention with the ANALYTIC expected kernel,
    averaged over the projection instances (tests)."""
    zq = jnp.einsum("bshd,mde->mbshe", q.astype(jnp.float32), params.proj)
    zk = jnp.einsum("bshd,mde->mbshe", k.astype(jnp.float32), params.proj)
    diff = zq[:, :, :, None] - zk[:, :, None, :]            # (m,B,Sq,Sk,H,e)
    kmat = jnp.mean(jnp.prod(kernel_1d(diff), axis=-1), axis=0)  # (B,Sq,Sk,H)
    num = jnp.einsum("bqkh,bkhd->bqhd", kmat, v.astype(jnp.float32))
    den = jnp.sum(kmat, axis=2)[..., None]
    return num / jnp.maximum(den, eps)
