"""GQA attention: training/prefill (query-chunked, remat-friendly) and decode
(single-token against a KV cache), with causal / sliding-window masks, optional
qk-norm and logit softcap, and cross-attention.

Layout: KV heads are expanded to full query heads with a static gather
(``jnp.take``) *before* the score einsum, so scores are laid out
(B, H, Sq, T) and shard over the 'heads' logical axis whenever n_heads divides
the model axis — (kv, group) factorized layouts do not shard nearly as well
under GSPMD.  The gathered K/V is cheap (it reads the small KV projection) and
fuses into the dot in most cases.

Decode caches are ring buffers: a layer with sliding window W keeps only
min(T, W) cache rows; the new token is written at ``pos % Tc`` and validity is
reconstructed from ``pos`` (all rows valid once the ring has wrapped).  This is
what makes the long_500k decode cells sub-quadratic *and* sub-linear-memory for
the windowed architectures.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..sharding import constrain
from .layers import apply_rope, rmsnorm, rmsnorm_specs
from .params import ParamSpec

Array = jnp.ndarray
NEG_INF = -1e30


def attn_specs(d_model: int, n_heads: int, n_kv: int, head_dim: int,
               qk_norm: bool = False) -> dict:
    s = {
        "wq": ParamSpec((d_model, n_heads, head_dim), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((d_model, n_kv, head_dim), ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((d_model, n_kv, head_dim), ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((n_heads, head_dim, d_model), ("heads", "head_dim", "embed")),
    }
    if qk_norm:
        s["q_norm"] = rmsnorm_specs(head_dim)
        s["k_norm"] = rmsnorm_specs(head_dim)
    return s


def _softcap(scores: Array, cap: float) -> Array:
    if cap and cap > 0.0:
        return cap * jnp.tanh(scores / cap)
    return scores


def _expand_kv(k: Array, n_heads: int) -> Array:
    """(B, T, KV, D) -> (B, T, H, D) by repeating each kv head g = H/KV times."""
    n_kv = k.shape[2]
    if n_kv == n_heads:
        return k
    idx = jnp.arange(n_heads, dtype=jnp.int32) // (n_heads // n_kv)
    return jnp.take(k, idx, axis=2)


def _sdpa(q: Array, k: Array, v: Array, *, q_pos: Array, k_pos: Array,
          causal: bool, window: int, softcap: float,
          k_valid: Array | None = None) -> Array:
    """q (B,Sq,H,D); k,v (B,T,H,D) already head-expanded; q_pos (B,Sq);
    k_pos (T,) absolute key positions; k_valid (B,T) optional validity mask."""
    d = q.shape[-1]
    scores = jnp.einsum("bshd,bthd->bhst", q, k,
                        preferred_element_type=jnp.float32)
    scores = scores / jnp.sqrt(d).astype(jnp.float32)
    scores = _softcap(scores, softcap)
    qp = q_pos[:, None, :, None]                       # (B,1,Sq,1)
    kp = k_pos[None, None, None, :]                    # (1,1,1,T)
    allow = jnp.ones(scores.shape[-2:], bool)[None, None]
    if causal:
        allow = allow & (kp <= qp)
    if window > 0:
        allow = allow & (kp > qp - window)
    if k_valid is not None:
        allow = allow & k_valid[:, None, None, :]
    scores = jnp.where(allow, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhst,bthd->bshd", probs.astype(v.dtype), v)


def multihead_attention(params: dict, x: Array, kv_src: Array, *,
                        q_pos: Array, k_pos: Array, causal: bool, window: int = 0,
                        softcap: float = 0.0, qk_norm: bool = False,
                        rope_theta: float = 0.0, q_chunk: int = 512,
                        norm_eps: float = 1e-5, return_kv: bool = False):
    """Full-sequence attention (train / prefill / encoder / cross).

    x (B,S,Dm) queries source; kv_src (B,T,Dm) keys/values source.
    rope_theta==0 disables RoPE (cross-attn, whisper).
    """
    dt = x.dtype
    b, s, _ = x.shape
    n_heads, head_dim = params["wq"].shape[1:]

    q = jnp.einsum("bsd,dhe->bshe", x, params["wq"].astype(dt))
    k = jnp.einsum("btd,dke->btke", kv_src, params["wk"].astype(dt))
    v = jnp.einsum("btd,dke->btke", kv_src, params["wv"].astype(dt))
    if qk_norm:
        q = rmsnorm(params["q_norm"], q, norm_eps)
        k = rmsnorm(params["k_norm"], k, norm_eps)
    if rope_theta:
        q = apply_rope(q, q_pos, rope_theta)
        k = apply_rope(k, k_pos[None, :].repeat(b, 0), rope_theta)
    kv = (k, v)
    q = constrain(q, ("batch", None, "heads", None))
    kf = constrain(_expand_kv(k, n_heads), ("batch", None, "heads", None))
    vf = constrain(_expand_kv(v, n_heads), ("batch", None, "heads", None))

    n_chunks = max(1, -(-s // q_chunk))
    if n_chunks <= 1:
        out = _sdpa(q, kf, vf, q_pos=q_pos, k_pos=k_pos, causal=causal,
                    window=window, softcap=softcap)
    else:
        pad = n_chunks * q_chunk - s
        q_p = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        qpos_p = jnp.pad(q_pos, ((0, 0), (0, pad)))
        q_c = q_p.reshape(b, n_chunks, q_chunk, n_heads, head_dim).transpose(
            1, 0, 2, 3, 4)
        qpos_c = qpos_p.reshape(b, n_chunks, q_chunk).transpose(1, 0, 2)

        @jax.checkpoint
        def chunk_fn(q_blk, qp_blk):
            return _sdpa(q_blk, kf, vf, q_pos=qp_blk, k_pos=k_pos, causal=causal,
                         window=window, softcap=softcap)

        out_c = jax.lax.map(lambda args: chunk_fn(*args), (q_c, qpos_c))
        out = out_c.transpose(1, 0, 2, 3, 4).reshape(
            b, n_chunks * q_chunk, n_heads, head_dim)[:, :s]

    y = jnp.einsum("bshe,hed->bsd", out, params["wo"].astype(dt))
    if return_kv:
        return y, kv
    return y


def decode_attention(params: dict, x: Array, cache_k: Array, cache_v: Array, *,
                     pos: Array, softcap: float = 0.0, qk_norm: bool = False,
                     rope_theta: float = 0.0, norm_eps: float = 1e-5):
    """One-token decode against a ring-buffer KV cache.

    x (B,1,Dm); cache_{k,v} (B,Tc,KV,D); pos (B,) absolute position of the new
    token.  Tc == window for sliding-window layers, == max seq for global ones.
    Returns (y, new_cache_k, new_cache_v).
    """
    dt = x.dtype
    b = x.shape[0]
    n_heads, head_dim = params["wq"].shape[1:]
    tc = cache_k.shape[1]

    q = jnp.einsum("bsd,dhe->bshe", x, params["wq"].astype(dt))
    k_new = jnp.einsum("bsd,dke->bske", x, params["wk"].astype(dt))
    v_new = jnp.einsum("bsd,dke->bske", x, params["wv"].astype(dt))
    if qk_norm:
        q = rmsnorm(params["q_norm"], q, norm_eps)
        k_new = rmsnorm(params["k_norm"], k_new, norm_eps)
    if rope_theta:
        q = apply_rope(q, pos[:, None], rope_theta)
        k_new = apply_rope(k_new, pos[:, None], rope_theta)

    bidx = jnp.arange(b, dtype=jnp.int32)
    widx = (pos % tc).astype(jnp.int32)
    cache_k = cache_k.at[bidx, widx].set(k_new[:, 0].astype(cache_k.dtype))
    cache_v = cache_v.at[bidx, widx].set(v_new[:, 0].astype(cache_v.dtype))

    # ring validity: rows 0..pos valid until the ring wraps, then all rows.
    slots = jnp.arange(tc, dtype=jnp.int32)
    k_valid = (slots[None, :] <= pos[:, None]) | (pos[:, None] >= tc)

    kf = _expand_kv(cache_k, n_heads).astype(dt)
    vf = _expand_kv(cache_v, n_heads).astype(dt)
    kf = constrain(kf, ("batch", "seq_shard", "heads", None))
    vf = constrain(vf, ("batch", "seq_shard", "heads", None))
    # positions are implicit in the rotated keys; ring rows are all in-window
    # by construction, so the mask is pure validity (no positional terms).
    out = _sdpa(q, kf, vf, q_pos=pos[:, None], k_pos=jnp.zeros((tc,), jnp.int32),
                causal=False, window=0, softcap=softcap, k_valid=k_valid)
    y = jnp.einsum("bshe,hed->bsd", out, params["wo"].astype(dt))
    return y, cache_k, cache_v


def cross_decode_attention(params: dict, x: Array, cross_k: Array, cross_v: Array,
                           *, softcap: float = 0.0, norm_eps: float = 1e-5):
    """Decode-time cross-attention against precomputed (frozen) source KV.
    x (B,1,Dm); cross_{k,v} (B,L,KV,D) filled at prefill."""
    dt = x.dtype
    n_heads = params["wq"].shape[1]
    q = jnp.einsum("bsd,dhe->bshe", x, params["wq"].astype(dt))
    kf = _expand_kv(cross_k, n_heads).astype(dt)
    vf = _expand_kv(cross_v, n_heads).astype(dt)
    l = kf.shape[1]
    out = _sdpa(q, kf, vf, q_pos=jnp.zeros((x.shape[0], 1), jnp.int32),
                k_pos=jnp.zeros((l,), jnp.int32), causal=False, window=0,
                softcap=softcap)
    return jnp.einsum("bshe,hed->bsd", out, params["wo"].astype(dt))
