"""Common layers: RMSNorm, RoPE, SwiGLU MLP, embeddings, positional encodings.

Pure-function style: every layer is ``apply(params, x, ...)`` plus a
``*_specs(...)`` builder returning the ParamSpec tree.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .params import ParamSpec

Array = jnp.ndarray


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm_specs(dim: int) -> dict:
    return {"scale": ParamSpec((dim,), (None,), init="ones")}


def rmsnorm(params: dict, x: Array, eps: float = 1e-5) -> Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * params["scale"].astype(jnp.float32)
    return out.astype(dt)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (B, S, H, D); positions: (B, S) int32 absolute positions."""
    freqs = rope_freqs(x.shape[-1], theta)                       # (D/2,)
    ang = positions.astype(jnp.float32)[..., None] * freqs       # (B, S, D/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(positions: Array, dim: int) -> Array:
    """(..., ) int32 -> (..., dim) float32 transformer sinusoids."""
    half = dim // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# MLP (SwiGLU)
# ---------------------------------------------------------------------------

def mlp_specs(d_model: int, d_ff: int) -> dict:
    return {
        "w_gate": ParamSpec((d_model, d_ff), ("embed", "mlp")),
        "w_up": ParamSpec((d_model, d_ff), ("embed", "mlp")),
        "w_down": ParamSpec((d_ff, d_model), ("mlp", "embed")),
    }


def mlp(params: dict, x: Array) -> Array:
    dt = x.dtype
    g = x @ params["w_gate"].astype(dt)
    u = x @ params["w_up"].astype(dt)
    return (jax.nn.silu(g) * u) @ params["w_down"].astype(dt)


# ---------------------------------------------------------------------------
# embeddings
# ---------------------------------------------------------------------------

def embed_specs(vocab: int, d_model: int) -> dict:
    return {"table": ParamSpec((vocab, d_model), ("vocab", "embed"), scale=1.0)}


def embed(params: dict, tokens: Array, dtype) -> Array:
    return params["table"].astype(dtype)[tokens]


def unembed(table: Array, h: Array) -> Array:
    """h (B, S, D) -> logits (B, S, V) in f32 (table may be tied embed)."""
    return h.astype(jnp.float32) @ table.astype(jnp.float32).T
