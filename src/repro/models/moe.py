"""Mixture-of-Experts FFN: top-k routing with sort-based capacity dispatch.

TPU adaptation (DESIGN.md §3 style): instead of per-expert token lists
(pointer-chasing) or giant one-hot dispatch tensors, tokens are *sorted* by
expert id, clamped to a per-expert capacity, gathered into a dense (E, C, D)
block, pushed through per-expert SwiGLU einsums, and scattered back with their
gate weights.  O(T log T) sort + O(T) gathers; the expert einsums are plain
MXU matmuls that shard over the 'experts' logical axis (expert parallelism)
when n_experts divides the model axis, else the 'mlp' axis (tensor
parallelism) — the sharding rules engine picks (repro/sharding.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import MoESpec
from ..sharding import constrain
from .params import ParamSpec

Array = jnp.ndarray


def moe_specs(d_model: int, spec: MoESpec) -> dict:
    e, f = spec.n_experts, spec.d_ff
    return {
        "router": ParamSpec((d_model, e), ("embed", None), scale=0.02),
        "w_gate": ParamSpec((e, d_model, f), ("experts", "embed", "mlp")),
        "w_up": ParamSpec((e, d_model, f), ("experts", "embed", "mlp")),
        "w_down": ParamSpec((e, f, d_model), ("experts", "mlp", "embed")),
    }


def _capacity(n_tokens: int, spec: MoESpec) -> int:
    c = int(spec.capacity_factor * n_tokens * spec.top_k / spec.n_experts) + 1
    return max(8, -(-c // 8) * 8)  # round up to a lane-friendly multiple of 8


def _dispatch_row(logits: Array, e: int, k: int, cap: int):
    """Per-row (one batch element, S tokens) top-k dispatch maps.

    Returns (tok_map (e*cap,) int32 with sentinel S, w_map (e*cap,) f32, aux).
    Row-local so the sort never crosses batch shards — a global argsort over
    the sharded token axis would all-gather the whole batch (267 GB/step at
    mixtral train_4k; this was measured, not hypothetical).
    """
    s = logits.shape[0]
    probs = jax.nn.softmax(logits, axis=-1)                       # (s, e)
    gate_w, gate_idx = jax.lax.top_k(probs, k)                    # (s, k)
    gate_w = gate_w / jnp.maximum(jnp.sum(gate_w, -1, keepdims=True), 1e-9)

    # Switch load-balance auxiliary loss: e * <fraction routed> . <router prob>
    routed = jnp.zeros((s, e), jnp.float32).at[
        jnp.arange(s)[:, None], gate_idx].set(1.0)
    aux = e * jnp.sum(jnp.mean(routed, axis=0) * jnp.mean(probs, axis=0))

    flat_e = gate_idx.reshape(-1)                                 # (s*k,)
    flat_w = gate_w.reshape(-1).astype(jnp.float32)
    flat_tok = jnp.repeat(jnp.arange(s, dtype=jnp.int32), k)
    order = jnp.argsort(flat_e)
    se, stok, sw = flat_e[order], flat_tok[order], flat_w[order]
    start = jnp.searchsorted(se, jnp.arange(e, dtype=se.dtype))
    pos_in_e = jnp.arange(s * k, dtype=jnp.int32) - start[se].astype(jnp.int32)
    keep = pos_in_e < cap
    slot = jnp.where(keep, se.astype(jnp.int32) * cap + pos_in_e, e * cap)

    tok_map = jnp.full((e * cap,), s, jnp.int32).at[slot].set(stok, mode="drop")
    w_map = jnp.zeros((e * cap,), jnp.float32).at[slot].set(sw, mode="drop")
    return tok_map, w_map, aux


def moe_ffn(params: dict, x: Array, spec: MoESpec):
    """x (B, S, D) -> (y (B, S, D), aux_loss scalar).

    Dispatch is row-local (per batch element): sort/scatter stay on the data
    shard; only the expert einsums see cross-shard traffic (expert weights
    gather or expert-parallel all-to-all, GSPMD's choice).  Dropped tokens
    (beyond capacity) contribute zero from this branch — the residual stream
    carries them through, the standard Switch behaviour.
    """
    dt = x.dtype
    b, s, d = x.shape
    e, k = spec.n_experts, spec.top_k
    cap = _capacity(s, spec)

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    tok_map, w_map, aux = jax.vmap(
        lambda lg: _dispatch_row(lg, e, k, cap))(logits)
    aux = jnp.mean(aux)

    xpad = jnp.concatenate([x, jnp.zeros((b, 1, d), dt)], axis=1)  # sentinel row
    xd = jnp.take_along_axis(
        xpad, tok_map[:, :, None].astype(jnp.int32), axis=1)       # (b, e*c, d)
    xd = xd.reshape(b, e, cap, d)
    xd = constrain(xd, ("batch", "experts", None, None))

    # ---- per-expert SwiGLU --------------------------------------------------
    g = jnp.einsum("becd,edf->becf", xd, params["w_gate"].astype(dt))
    u = jnp.einsum("becd,edf->becf", xd, params["w_up"].astype(dt))
    y = jnp.einsum("becf,efd->becd", jax.nn.silu(g) * u,
                   params["w_down"].astype(dt))
    y = constrain(y, ("batch", "experts", None, None))

    # ---- combine ------------------------------------------------------------
    # vmap'd per-row scatter: an explicit arange(b) batch index makes GSPMD
    # replicate the whole (B, S, D) output (measured 21 GB/dev at llama4
    # prefill_32k); with a scatter batch dim it stays batch-sharded.
    yw = y.reshape(b, e * cap, d) * w_map[:, :, None].astype(dt)

    def combine_row(tok_map_r, yw_r):
        return jnp.zeros((s + 1, d), dt).at[tok_map_r].add(yw_r)

    out = jax.vmap(combine_row)(tok_map, yw)
    return out[:, :s], aux
