"""RWKV6 "Finch" — attention-free time mix with data-dependent per-channel
decay, plus the squared-ReLU channel mix.

The decay w_t = exp(-exp(w0 + lora(x_t))) is the architecture's hallmark: the
per-channel log-decay depends on the input.  That same data dependence makes
the usual log-space chunked factorization numerically unsafe (exp(-L_s) of an
unbounded cumulative sum), so training/prefill run the recurrence as a
lax.scan over time — each step is a batched (B,H,D,D) rank-1 update, which the
dry-run lowers to a while loop with exact FLOP accounting.  Decode is the O(1)
single-step update (this is why rwkv6 runs the long_500k cell).

State per layer: wkv (B,H,D,D) f32, plus two token-shift rows (B,Dm).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..sharding import constrain
from .params import ParamSpec

Array = jnp.ndarray

_LORA_DIM = 64


def rwkv6_specs(d_model: int, n_heads: int, head_dim: int, d_ff: int) -> dict:
    hd = n_heads * head_dim
    return {
        # time mix
        "mu_r": ParamSpec((d_model,), (None,), init="zeros"),
        "mu_k": ParamSpec((d_model,), (None,), init="zeros"),
        "mu_v": ParamSpec((d_model,), (None,), init="zeros"),
        "mu_g": ParamSpec((d_model,), (None,), init="zeros"),
        "mu_w": ParamSpec((d_model,), (None,), init="zeros"),
        "w_r": ParamSpec((d_model, n_heads, head_dim), ("embed", "heads", "head_dim")),
        "w_k": ParamSpec((d_model, n_heads, head_dim), ("embed", "heads", "head_dim")),
        "w_v": ParamSpec((d_model, n_heads, head_dim), ("embed", "heads", "head_dim")),
        "w_g": ParamSpec((d_model, hd), ("embed", "mlp")),
        "w_o": ParamSpec((n_heads, head_dim, d_model), ("heads", "head_dim", "embed")),
        "w0": ParamSpec((n_heads, head_dim), ("heads", "head_dim"), init="zeros"),
        "w_lora_a": ParamSpec((d_model, _LORA_DIM), ("embed", None), scale=0.02),
        "w_lora_b": ParamSpec((_LORA_DIM, n_heads, head_dim), (None, "heads", "head_dim"),
                              scale=0.02),
        "u_bonus": ParamSpec((n_heads, head_dim), ("heads", "head_dim"), init="zeros"),
        "ln_x": ParamSpec((n_heads, head_dim), ("heads", "head_dim"), init="ones"),
        # channel mix
        "mu_ck": ParamSpec((d_model,), (None,), init="zeros"),
        "mu_cr": ParamSpec((d_model,), (None,), init="zeros"),
        "w_ck": ParamSpec((d_model, d_ff), ("embed", "mlp")),
        "w_cv": ParamSpec((d_ff, d_model), ("mlp", "embed")),
        "w_cr": ParamSpec((d_model, d_model), ("embed", None)),
    }


def _shift(x: Array, prev: Array | None) -> Array:
    """Token shift: y[t] = x[t-1]; first row from carry (zeros at stream start).
    x (B,S,D); prev (B,D) or None."""
    first = jnp.zeros_like(x[:, :1]) if prev is None else prev[:, None].astype(x.dtype)
    return jnp.concatenate([first, x[:, :-1]], axis=1)


def _mix(x: Array, xs: Array, mu: Array) -> Array:
    return x + (xs - x) * mu.astype(x.dtype)


def wkv_scan(r: Array, k: Array, v: Array, logw: Array, u: Array,
             s0: Array | None = None, chunk: int = 64):
    """The RWKV6 recurrence.

      y_t   = r_t . (S_{t-1} + diag(u) k_t v_t^T)
      S_t   = diag(w_t) S_{t-1} + k_t v_t^T

    r,k,v (B,S,H,D); logw (B,S,H,D) <= 0; u (H,D); s0 (B,H,D,D).
    Returns (y (B,S,H,D) f32, s_final).

    The time scan is nested: an outer scan over S/chunk blocks whose body is
    ``jax.checkpoint``-ed, so backprop stores the (B,H,D,D) state only at
    chunk boundaries and recomputes inside — without this, the per-step
    residuals are S x (B,H,D,D) floats (~17 GB/device at train_4k).  The
    sqrt(S)-ish default chunk balances stored boundary states (S/chunk) vs
    the transient per-step states of the one chunk being recomputed (chunk).
    """
    bsz, s, h, d = r.shape
    if s % chunk:
        chunk = s                                 # short sequences: one chunk
    nc = s // chunk
    if s0 is None:
        s0 = jnp.zeros((bsz, h, d, d), jnp.float32)

    def step(state, inp):
        rt, kt, vt, lwt = inp                      # (B,H,D) each
        kv = kt[..., :, None] * vt[..., None, :]   # (B,H,D,D)
        read = state + u[None, :, :, None] * kv
        yt = jnp.einsum("bhi,bhij->bhj", rt, read)
        state = jnp.exp(lwt)[..., :, None] * state + kv
        return state, yt

    def to_chunks(a):
        # (B,S,H,D) -> (nc, chunk, B, H, D)
        out = a.astype(jnp.float32).transpose(1, 0, 2, 3).reshape(
            nc, chunk, bsz, h, d)
        return constrain(out, (None, None, "batch", "heads", None))

    @jax.checkpoint
    def chunk_body(state, ch):
        return jax.lax.scan(step, state, ch)

    xs = (to_chunks(r), to_chunks(k), to_chunks(v), to_chunks(logw))
    s_fin, ys = jax.lax.scan(chunk_body, s0.astype(jnp.float32), xs)
    ys = ys.reshape(s, bsz, h, d)
    return ys.transpose(1, 0, 2, 3), s_fin


def wkv_step(state: Array, r: Array, k: Array, v: Array, logw: Array, u: Array):
    """One-token update. state (B,H,D,D); r,k,v,logw (B,H,D)."""
    kv = k[..., :, None] * v[..., None, :]
    y = jnp.einsum("bhi,bhij->bhj", r, state + u[None, :, :, None] * kv)
    state = jnp.exp(logw)[..., :, None] * state + kv
    return y, state


def _group_norm(y: Array, scale: Array, eps: float = 1e-5) -> Array:
    """Per-head layernorm of y (B,S,H,D)."""
    mean = jnp.mean(y, axis=-1, keepdims=True)
    var = jnp.var(y, axis=-1, keepdims=True)
    return (y - mean) * jax.lax.rsqrt(var + eps) * scale[None, None]


def time_mix(params: dict, x: Array, *, shift_state: Array | None = None,
             wkv_state: Array | None = None, decode: bool = False):
    """RWKV6 time mix.  x (B,S,Dm).  Returns (y, (new_shift, new_wkv))."""
    dt = x.dtype
    bsz, s, dm = x.shape
    h, d = params["u_bonus"].shape
    xs = _shift(x, shift_state)
    xr = _mix(x, xs, params["mu_r"])
    xk = _mix(x, xs, params["mu_k"])
    xv = _mix(x, xs, params["mu_v"])
    xg = _mix(x, xs, params["mu_g"])
    xw = _mix(x, xs, params["mu_w"])

    r = jnp.einsum("bsd,dhe->bshe", xr, params["w_r"].astype(dt))
    k = jnp.einsum("bsd,dhe->bshe", xk, params["w_k"].astype(dt))
    v = jnp.einsum("bsd,dhe->bshe", xv, params["w_v"].astype(dt))
    g = jax.nn.silu(xg @ params["w_g"].astype(dt))
    bshe = ("batch", None, "heads", None)
    r = constrain(r, bshe)
    k = constrain(k, bshe)
    v = constrain(v, bshe)

    lora = jnp.einsum("bsl,lhe->bshe", jnp.tanh(xw @ params["w_lora_a"].astype(dt)),
                      params["w_lora_b"].astype(dt))
    logw = -jnp.exp(params["w0"].astype(jnp.float32)[None, None] +
                    lora.astype(jnp.float32))          # (B,S,H,D) <= 0
    logw = constrain(logw, bshe)

    u = params["u_bonus"].astype(jnp.float32)
    if decode:
        y, new_state = wkv_step(
            jnp.zeros((bsz, h, d, d), jnp.float32) if wkv_state is None else wkv_state,
            r[:, 0].astype(jnp.float32), k[:, 0].astype(jnp.float32),
            v[:, 0].astype(jnp.float32), logw[:, 0], u)
        y = y[:, None]
    else:
        y, new_state = wkv_scan(r, k, v, logw, u, s0=wkv_state)

    y = _group_norm(y, params["ln_x"].astype(jnp.float32))
    y = (y.reshape(bsz, -1, h * d).astype(dt)) * g
    y = jnp.einsum("bshe,hed->bsd", y.reshape(bsz, -1, h, d),
                   params["w_o"].astype(dt))
    return y, (x[:, -1].astype(jnp.float32), new_state)


def channel_mix(params: dict, x: Array, *, shift_state: Array | None = None):
    """RWKV channel mix.  Returns (y, new_shift)."""
    dt = x.dtype
    xs = _shift(x, shift_state)
    xk = _mix(x, xs, params["mu_ck"])
    xr = _mix(x, xs, params["mu_cr"])
    vv = jnp.square(jax.nn.relu(xk @ params["w_ck"].astype(dt)))
    vv = constrain(vv, ("batch", None, "mlp"))
    out = jax.nn.sigmoid(xr @ params["w_cr"].astype(dt)) * (vv @ params["w_cv"].astype(dt))
    return out, x[:, -1].astype(jnp.float32)
