"""Parameter-spec infrastructure: shapes + logical sharding axes + init in one
declarative tree.  ``abstract(...)`` materializes ShapeDtypeStructs only, so
the dry-run never allocates."""
from __future__ import annotations

import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class ParamSpec(NamedTuple):
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]   # logical axis per dim (see repro.sharding)
    init: str = "normal"           # 'normal' | 'zeros' | 'ones'
    scale: float | None = None     # stddev; None -> 1/sqrt(fan_in = shape[0])

    def initializer(self, key: jax.Array, dtype) -> jax.Array:
        if self.init == "zeros":
            return jnp.zeros(self.shape, dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, dtype)
        std = self.scale if self.scale is not None else 1.0 / math.sqrt(
            max(self.shape[0], 1))
        return std * jax.random.normal(key, self.shape, dtype)


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def init_params(specs: Any, key: jax.Array, dtype=jnp.float32) -> Any:
    """Materialize a ParamSpec tree; keys derived from tree paths (stable
    across spec-tree refactors that keep paths)."""
    leaves = jax.tree_util.tree_flatten_with_path(specs, is_leaf=_is_spec)[0]
    out = {}
    for path, spec in leaves:
        pkey = jax.random.fold_in(key, hash(jax.tree_util.keystr(path)) & 0x7FFFFFFF)
        out[path] = spec.initializer(pkey, dtype)
    paths = [p for p, _ in leaves]
    treedef = jax.tree_util.tree_structure(specs, is_leaf=_is_spec)
    return jax.tree_util.tree_unflatten(treedef, [out[p] for p in paths])


def abstract(specs: Any, dtype=jnp.float32) -> Any:
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, dtype), specs,
                        is_leaf=_is_spec)


def axes_tree(specs: Any) -> Any:
    return jax.tree.map(lambda s: s.axes, specs, is_leaf=_is_spec)


def stack_specs(spec_tree: Any, n: int) -> Any:
    """Add a leading scan (layer-group) dim to every spec; unsharded axis."""
    return jax.tree.map(
        lambda s: ParamSpec((n,) + s.shape, (None,) + s.axes, s.init, s.scale),
        spec_tree, is_leaf=_is_spec)


def count_params(specs: Any) -> int:
    return sum(math.prod(s.shape) for s in jax.tree.leaves(specs, is_leaf=_is_spec))
