"""Mamba2 (SSD) block — chunked state-space duality algorithm.

Train/prefill use the chunked SSD form: within a chunk of length Q everything
is dense matmuls (MXU work); chunk states are carried by a short lax.scan of
length S/Q.  All decays are exp of non-positive f32 logs, so nothing can
overflow.  Decode is the O(1) recurrent update.

Shapes: d_inner = expand * d_model, H = d_inner // head_dim ssm heads of head
dim P, shared state dim N per head (ngroups = 1, as in zamba2).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..configs.base import SSMSpec
from ..sharding import constrain
from .params import ParamSpec

Array = jnp.ndarray

_CHUNK = 256


class Mamba2Dims(NamedTuple):
    d_inner: int
    n_heads: int
    head_dim: int
    state: int
    conv_width: int
    conv_dim: int   # channels passing through the causal conv: d_inner + 2N


def mamba2_dims(d_model: int, spec: SSMSpec) -> Mamba2Dims:
    d_inner = spec.expand * d_model
    n_heads = d_inner // spec.head_dim
    return Mamba2Dims(d_inner=d_inner, n_heads=n_heads, head_dim=spec.head_dim,
                      state=spec.state_dim, conv_width=spec.conv_width,
                      conv_dim=d_inner + 2 * spec.state_dim)


def mamba2_specs(d_model: int, spec: SSMSpec) -> dict:
    dims = mamba2_dims(d_model, spec)
    # in_proj -> [z (d_inner), xBC (conv_dim), dt (H)]
    proj_out = dims.d_inner + dims.conv_dim + dims.n_heads
    return {
        "in_proj": ParamSpec((d_model, proj_out), ("embed", "mlp")),
        "conv_w": ParamSpec((dims.conv_width, dims.conv_dim), (None, "mlp"), scale=0.5),
        "conv_b": ParamSpec((dims.conv_dim,), ("mlp",), init="zeros"),
        "a_log": ParamSpec((dims.n_heads,), ("ssm_heads",), init="zeros"),
        "dt_bias": ParamSpec((dims.n_heads,), ("ssm_heads",), init="zeros"),
        "d_skip": ParamSpec((dims.n_heads,), ("ssm_heads",), init="ones"),
        "norm_scale": ParamSpec((dims.d_inner,), ("mlp",), init="ones"),
        "out_proj": ParamSpec((dims.d_inner, d_model), ("mlp", "embed")),
    }


def _split_proj(proj: Array, dims: Mamba2Dims):
    z, xbc, dt = jnp.split(proj, [dims.d_inner, dims.d_inner + dims.conv_dim], axis=-1)
    return z, xbc, dt


def _causal_conv(xbc: Array, w: Array, b: Array, state: Array | None):
    """Depthwise causal conv1d.  xbc (B,S,C), w (W,C).  state (B,W-1,C) holds
    the trailing context from the previous segment (zeros at start)."""
    bsz, s, c = xbc.shape
    width = w.shape[0]
    if state is None:
        state = jnp.zeros((bsz, width - 1, c), xbc.dtype)
    full = jnp.concatenate([state.astype(xbc.dtype), xbc], axis=1)  # (B, S+W-1, C)
    out = jnp.zeros((bsz, s, c), jnp.float32)
    for i in range(width):  # width is 4: unrolled taps, no conv primitive needed
        out = out + full[:, i:i + s, :].astype(jnp.float32) * w[i].astype(jnp.float32)
    out = jax.nn.silu(out + b.astype(jnp.float32)).astype(xbc.dtype)
    new_state = full[:, s:, :]
    return out, new_state


def _gated_rmsnorm(y: Array, z: Array, scale: Array, eps: float = 1e-5) -> Array:
    yf = (y * jax.nn.silu(z)).astype(jnp.float32)
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    return (yf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(y.dtype)


def ssd_chunked(x: Array, b_mat: Array, c_mat: Array, dt: Array, a: Array,
                h0: Array | None = None, chunk: int = _CHUNK):
    """Chunked SSD scan.

    x   (B, S, H, P)   inputs per head
    b_mat, c_mat (B, S, N)  shared input/output projections (ngroups=1)
    dt  (B, S, H)      positive step sizes (softplus already applied)
    a   (H,)           negative per-head decay rates (-exp(a_log))
    h0  (B, H, P, N)   initial state (decode/prefill continuation)
    Returns (y (B,S,H,P), h_final (B,H,P,N)).
    """
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    q = min(chunk, s)
    if s % q:
        raise ValueError(f"seq {s} not divisible by chunk {q}")
    nc = s // q

    xd = (x * dt[..., None]).astype(jnp.float32)                  # dt-weighted input
    la = a[None, None, :] * dt                                    # (B,S,H) log-decay <= 0
    xc = xd.reshape(bsz, nc, q, h, p)
    bc = b_mat.reshape(bsz, nc, q, n).astype(jnp.float32)
    cc = c_mat.reshape(bsz, nc, q, n).astype(jnp.float32)
    lac = la.reshape(bsz, nc, q, h)
    lcum = jnp.cumsum(lac, axis=2)                                # inclusive, <= 0

    # intra-chunk: att[t, s] = (C_t . B_s) * exp(L_t - L_s) for s <= t
    rel = lcum[:, :, :, None, :] - lcum[:, :, None, :, :]         # (B,nc,q,q,H), <=0 on mask
    mask = jnp.tril(jnp.ones((q, q), bool))
    dec = jnp.where(mask[None, None, :, :, None], jnp.exp(rel), 0.0)
    cb = jnp.einsum("bctn,bcsn->bcts", cc, bc)                    # (B,nc,q,q)
    y_intra = jnp.einsum("bcts,bctsh,bcshp->bcthp", cb, dec, xc)

    # chunk summaries: state_c = sum_s exp(L_end - L_s) B_s (x dt)_s
    dec_end = jnp.exp(lcum[:, :, -1:, :] - lcum)                  # (B,nc,q,H)
    state_c = jnp.einsum("bcsn,bcsh,bcshp->bchpn", bc, dec_end, xc)
    gamma = jnp.exp(lcum[:, :, -1, :])                            # (B,nc,H) chunk decay

    def step(hprev, inp):
        st, g = inp                                               # (B,H,P,N), (B,H)
        hnew = hprev * g[:, :, None, None] + st
        return hnew, hprev                                        # emit state *before* chunk

    if h0 is None:
        h0 = jnp.zeros((bsz, h, p, n), jnp.float32)
    hT, hprevs = jax.lax.scan(step, h0.astype(jnp.float32),
                              (state_c.transpose(1, 0, 2, 3, 4),
                               gamma.transpose(1, 0, 2)))
    hprevs = hprevs.transpose(1, 0, 2, 3, 4)                      # (B,nc,H,P,N)

    # inter-chunk: y_t += C_t . (exp(L_t) * H_before_chunk)
    y_inter = jnp.einsum("bctn,bcth,bchpn->bcthp", cc, jnp.exp(lcum), hprevs)
    y = (y_intra + y_inter).reshape(bsz, s, h, p)
    return y, hT


def ssd_decode_step(h: Array, x: Array, b_mat: Array, c_mat: Array, dt: Array,
                    a: Array):
    """One-token SSD update.  h (B,H,P,N); x (B,H,P); b,c (B,N); dt (B,H)."""
    g = jnp.exp(a[None, :] * dt)                                  # (B,H)
    xd = (x * dt[..., None]).astype(jnp.float32)
    upd = jnp.einsum("bhp,bn->bhpn", xd, b_mat.astype(jnp.float32))
    hnew = h * g[:, :, None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", hnew, c_mat.astype(jnp.float32))
    return y, hnew


def mamba2_block(params: dict, x: Array, spec: SSMSpec, *,
                 conv_state: Array | None = None, ssm_state: Array | None = None,
                 decode: bool = False):
    """Apply one Mamba2 block.  x (B,S,Dm) (S==1 with decode=True).

    Returns (y (B,S,Dm), (new_conv_state, new_ssm_state)).
    """
    dt_ = x.dtype
    dims = mamba2_dims(x.shape[-1], spec)
    bsz, s, _ = x.shape
    proj = x @ params["in_proj"].astype(dt_)
    z, xbc, dtr = _split_proj(proj, dims)
    dt = jax.nn.softplus(dtr.astype(jnp.float32) +
                         params["dt_bias"].astype(jnp.float32))   # (B,S,H)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))             # (H,) < 0

    if decode:
        # roll the conv window by one token
        if conv_state is None:
            conv_state = jnp.zeros((bsz, dims.conv_width - 1, dims.conv_dim), dt_)
        xbc_f, new_conv = _causal_conv(xbc, params["conv_w"], params["conv_b"],
                                       conv_state)
        xs, bm, cm = jnp.split(xbc_f[:, 0], [dims.d_inner, dims.d_inner + dims.state],
                               axis=-1)
        xh = xs.reshape(bsz, dims.n_heads, dims.head_dim).astype(jnp.float32)
        if ssm_state is None:
            ssm_state = jnp.zeros((bsz, dims.n_heads, dims.head_dim, dims.state),
                                  jnp.float32)
        y, hnew = ssd_decode_step(ssm_state, xh, bm, cm, dt[:, 0], a)
        y = y + params["d_skip"].astype(jnp.float32)[None, :, None] * xh
        y = y.reshape(bsz, 1, dims.d_inner).astype(dt_)
        y = _gated_rmsnorm(y, z, params["norm_scale"])
        return y @ params["out_proj"].astype(dt_), (new_conv, hnew)

    xbc_f, new_conv = _causal_conv(xbc, params["conv_w"], params["conv_b"], conv_state)
    xs, bm, cm = jnp.split(xbc_f, [dims.d_inner, dims.d_inner + dims.state], axis=-1)
    xh = xs.reshape(bsz, s, dims.n_heads, dims.head_dim)
    xh = constrain(xh, ("batch", None, "ssm_heads", None))
    y, hT = ssd_chunked(xh, bm, cm, dt, a, h0=ssm_state)
    y = y + params["d_skip"].astype(jnp.float32)[None, None, :, None] * \
        xh.astype(jnp.float32)
    y = y.reshape(bsz, s, dims.d_inner).astype(dt_)
    y = _gated_rmsnorm(y, z, params["norm_scale"])
    return y @ params["out_proj"].astype(dt_), (new_conv, hT)


def mamba2_ref_scan(x: Array, b_mat: Array, c_mat: Array, dt: Array, a: Array,
                    h0: Array | None = None):
    """O(S) sequential oracle for ssd_chunked (tests)."""
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((bsz, h, p, n), jnp.float32)

    def step(hprev, inp):
        xt, bt, ct, dtt = inp
        y, hnew = ssd_decode_step(hprev, xt, bt, ct, dtt, a)
        return hnew, y

    hT, ys = jax.lax.scan(step, h0.astype(jnp.float32),
                          (x.transpose(1, 0, 2, 3).astype(jnp.float32),
                           b_mat.transpose(1, 0, 2), c_mat.transpose(1, 0, 2),
                           dt.transpose(1, 0, 2)))
    return ys.transpose(1, 0, 2, 3), hT
